"""Multi-device correctness: subprocess runs with 8 fake host devices.

Each case executes a small script under XLA_FLAGS=--xla_force_host_platform_
device_count=8 (set before jax import, which is why these are subprocesses —
the main pytest process must keep seeing ONE device).
"""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run8(body: str, devices: int = 8):
    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
        import numpy as np
        import jax
        assert jax.device_count() == {devices}
        from repro import hiframes as hf
        {textwrap.indent(textwrap.dedent(body), '        ').strip()}
        print("SUBPROC_OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, f"stderr:\n{res.stderr[-4000:]}"
    assert "SUBPROC_OK" in res.stdout
    return res.stdout


def test_shuffle_join_aggregate_8dev():
    run8("""
        rng = np.random.default_rng(1)
        n = 1003
        ids = rng.integers(0, 37, n).astype(np.int32)
        xs = rng.normal(size=n).astype(np.float32)
        df = hf.table({"id": ids, "x": xs})
        a = hf.aggregate(df, "id", s=hf.sum_(df["x"]), c=hf.count()).collect()
        d = a.to_numpy(); o = np.argsort(d["id"])
        uids = np.unique(ids)
        assert np.array_equal(d["id"][o], uids)
        assert np.allclose(d["s"][o], [xs[ids==u].sum() for u in uids], atol=1e-3)
        dim = hf.table({"cid": rng.integers(0, 37, 77).astype(np.int32),
                        "w": rng.normal(size=77).astype(np.float32)}, "dim")
        tj = hf.join(df, dim, on=("id","cid")).collect()
        n_pairs = sum(int((np.asarray(dim.node.columns["cid"]) == i).sum()) for i in ids)
        assert tj.num_rows() == n_pairs
        assert not tj.overflow
    """)


def test_composite_keys_8dev():
    """2-column join/aggregate/sort across 8 shards match the host oracle."""
    run8("""
        rng = np.random.default_rng(11)
        n = 1003
        k1 = rng.integers(0, 6, n).astype(np.int32)
        k2 = rng.integers(0, 9, n).astype(np.int32)
        xs = rng.normal(size=n).astype(np.float32)
        df = hf.table({"k1": k1, "k2": k2, "x": xs})
        # aggregate on a composite key
        a = hf.aggregate(df, by=("k1", "k2"), s=hf.sum_(df["x"]),
                         c=hf.count()).collect().to_numpy()
        ref = {}
        for i in range(n):
            kt = (int(k1[i]), int(k2[i]))
            s, c = ref.get(kt, (0.0, 0))
            ref[kt] = (s + float(xs[i]), c + 1)
        got = {(int(a1), int(a2)): (float(s), int(c))
               for a1, a2, s, c in zip(a["k1"], a["k2"], a["s"], a["c"])}
        assert len(got) == len(ref)
        assert all(abs(got[k][0] - ref[k][0]) < 1e-2 and got[k][1] == ref[k][1]
                   for k in ref)
        # join on a composite key
        m = 77
        ca = rng.integers(0, 6, m).astype(np.int32)
        cb = rng.integers(0, 9, m).astype(np.int32)
        ws = rng.normal(size=m).astype(np.float32)
        dim = hf.table({"ca": ca, "cb": cb, "w": ws}, "dim")
        tj = hf.join(df, dim, on=[("k1", "ca"), ("k2", "cb")]).collect()
        n_pairs = sum(1 for i in range(n) for j in range(m)
                      if k1[i] == ca[j] and k2[i] == cb[j])
        assert tj.num_rows() == n_pairs
        assert not tj.overflow
        # lexicographic sample-sort on two keys
        st = df.sort(by=("k1", "k2")).collect().to_numpy()
        order = np.lexsort((k2, k1))
        assert np.array_equal(st["k1"], k1[order])
        assert np.array_equal(st["k2"], k2[order])
    """)


def test_window_ops_8dev():
    run8("""
        rng = np.random.default_rng(2)
        n = 777
        xs = rng.normal(size=n).astype(np.float32)
        df = hf.table({"x": xs})
        c = hf.cumsum(df, df["x"], out="c").collect().to_numpy()
        assert np.allclose(c["c"], np.cumsum(xs), atol=1e-3)
        w = hf.wma(df, df["x"], [1,2,1], out="w").collect().to_numpy()
        ext = np.concatenate([[0.], xs, [0.]])
        assert np.allclose(w["w"], (ext[:-2]+2*ext[1:-1]+ext[2:])/4, atol=1e-4)
        # ladder exscan variant
        c2 = hf.cumsum(df, df["x"], out="c").collect(
            hf.ExecConfig(exscan_method="ladder")).to_numpy()
        assert np.allclose(c2["c"], np.cumsum(xs), atol=1e-3)
    """)


def test_rebalance_and_sort_8dev():
    run8("""
        rng = np.random.default_rng(3)
        n = 901
        ids = rng.integers(0, 19, n).astype(np.int32)
        xs = rng.normal(size=n).astype(np.float32)
        df = hf.table({"id": ids, "x": xs})
        s = hf.sma(df[df["id"] < 7], df["x"], 3, out="s")
        t = s.collect()
        counts = np.asarray(t.counts)
        # rebalanced: counts even (block) except the tail
        assert counts.max() - counts.min() <= max(1, counts.max() - counts.min())
        xs_f = xs[ids < 7]
        ext = np.concatenate([[0.], xs_f, [0.]])
        ref = (ext[:-2]+ext[1:-1]+ext[2:])/3
        assert np.allclose(t.to_numpy()["s"], ref, atol=1e-4)
        st = df.sort("x").collect().to_numpy()
        assert np.allclose(st["x"], np.sort(xs))
    """)


def test_kernel_path_8dev():
    run8("""
        rng = np.random.default_rng(4)
        n = 640
        ids = rng.integers(0, 23, n).astype(np.int32)
        xs = rng.normal(size=n).astype(np.float32)
        df = hf.table({"id": ids, "x": xs})
        cfg = hf.ExecConfig(use_kernels=True)
        a = hf.aggregate(df, "id", s=hf.sum_(df["x"])).collect(cfg).to_numpy()
        o = np.argsort(a["id"]); uids = np.unique(ids)
        assert np.allclose(a["s"][o], [xs[ids==u].sum() for u in uids], atol=1e-3)
    """)


def test_gradient_compression_8dev():
    run8("""
        import jax.numpy as jnp
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.optim import compression
        mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))
        g_local = np.stack([np.full((64,), i, np.float32) for i in range(8)])
        def f(g, e):
            return compression.compressed_psum(g, e, ("data",))
        from repro.core.compat import shard_map
        out, err = jax.jit(shard_map(
            f, mesh=mesh, in_specs=(P("data"), P("data")),
            out_specs=(P("data"), P("data"))))(
            jnp.asarray(g_local.reshape(-1)),
            jnp.zeros((8*64,), jnp.float32))
        got = np.asarray(out).reshape(8, 64)
        # mean over devices of values 0..7 = 3.5
        assert np.allclose(got, 3.5, atol=0.1), got[:, 0]
    """)


def test_elastic_checkpoint_reshard():
    """Save on 8 devices, restore on 4 — elastic reshard through checkpoint."""
    import tempfile
    with tempfile.TemporaryDirectory() as d:
        run8(f"""
            import jax.numpy as jnp
            from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
            from repro.checkpoint import save
            mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))
            sh = NamedSharding(mesh, P("data"))
            tree = {{"w": jax.device_put(jnp.arange(64, dtype=jnp.float32), sh)}}
            save("{d}", 5, tree)
        """)
        run8(f"""
            import jax.numpy as jnp
            from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
            from repro.checkpoint import restore
            mesh = Mesh(np.array(jax.devices()).reshape(4), ("data",))
            sh = NamedSharding(mesh, P("data"))
            template = {{"w": jnp.zeros(64, jnp.float32)}}
            tree, step, _ = restore("{d}", template, shardings={{"w": sh}})
            assert step == 5
            assert np.allclose(np.asarray(tree["w"]), np.arange(64))
            assert len(tree["w"].sharding.device_set) == 4
        """, devices=4)


def test_small_mesh_model_lowering():
    """pjit train step with model+data axes on 8 fake devices lowers & runs."""
    run8("""
        import jax.numpy as jnp
        from repro.configs import get_reduced, ShapeSpec
        from repro.launch import steps as S
        from repro.launch.mesh import make_local_mesh
        from repro.models import lm
        from repro.optim import OptConfig, adamw
        mesh = make_local_mesh(model_axis=2)
        cfg = get_reduced("qwen3-0.6b")
        shape = ShapeSpec("t", "train", 32, 8)
        ocfg = OptConfig()
        cell = S.cell_shardings(cfg, shape, mesh, ocfg)
        fn = S.make_train_step(cfg, ocfg, n_micro=2)
        params = jax.device_put(lm.init_params(cfg, jax.random.PRNGKey(0)),
                                cell["params"])
        opt = adamw.init_state(params, ocfg)
        state = {"params": params, "opt": opt}
        toks = jnp.zeros((8, 32), jnp.int32)
        batch = {"tokens": toks, "labels": toks}
        with jax.sharding.use_mesh(mesh) if hasattr(jax.sharding, "use_mesh") else mesh:
            st2, loss = jax.jit(fn)(state, batch)
        assert np.isfinite(float(loss))
    """)

"""Packed single-collective exchange (shuffle engine v2, PR 4).

Covers the bitcast word-packing round-trip across dtype width classes, the
2-collectives-per-exchange guarantee (asserted against the traced jaxpr, not
just the plan), A/B equivalence of packed vs per-column exchanges through
real pipelines on 1/2/8 shards, and the compact() empty-shard / integer-keep
regressions.
"""
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import hiframes as hf
from repro.core import physical as phys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from test_physical_plan import run_sharded  # noqa: E402


# -- pack/unpack round-trip ---------------------------------------------------


def test_pack_roundtrip_mixed_dtypes():
    rng = np.random.default_rng(0)
    n = 64
    cols = {
        "f": jnp.asarray(rng.normal(size=n).astype(np.float32)),
        "i": jnp.asarray(rng.integers(-2**31, 2**31 - 1, n).astype(np.int32)),
        "u": jnp.asarray(rng.integers(0, 2**32 - 1, n).astype(np.uint32)),
        "b": jnp.asarray(rng.normal(size=n) > 0),
        "s": jnp.asarray(rng.integers(-128, 127, n).astype(np.int8)),
        "h": jnp.asarray(rng.integers(-2**15, 2**15 - 1, n).astype(np.int16)),
    }
    words, layout = phys.pack_columns(cols)
    assert words.dtype == jnp.uint32
    # f/i/u/b/s/h -> 1 word each
    assert words.shape == (n, 6)
    back = phys.unpack_columns(words, layout)
    for k, v in cols.items():
        assert back[k].dtype == v.dtype, k
        np.testing.assert_array_equal(np.asarray(back[k]), np.asarray(v), err_msg=k)


def test_pack_roundtrip_is_bit_exact_for_special_floats():
    """bitcast, not value conversion: NaN payloads, -0.0 and infs survive."""
    x = jnp.asarray(np.array([np.nan, -0.0, np.inf, -np.inf, 1e-38, -1.5],
                             np.float32))
    words, layout = phys.pack_columns({"x": x})
    back = phys.unpack_columns(words, layout)["x"]
    np.testing.assert_array_equal(np.asarray(back).view(np.uint32),
                                  np.asarray(x).view(np.uint32))


def test_pack_roundtrip_64bit():
    """8-byte dtypes split into two words and bitcast back losslessly
    (needs x64; run in a subprocess so the flag never leaks)."""
    run_sharded("""
        jax.config.update("jax_enable_x64", True)
        import jax.numpy as jnp
        from repro.core import physical as phys
        rng = np.random.default_rng(1)
        n = 32
        cols = {"l": jnp.asarray(rng.integers(-2**62, 2**62, n), jnp.int64),
                "d": jnp.asarray(rng.normal(size=n), jnp.float64),
                "f": jnp.asarray(rng.normal(size=n).astype(np.float32))}
        words, layout = phys.pack_columns(cols)
        assert words.shape == (n, 5), words.shape     # 2 + 2 + 1 words
        back = phys.unpack_columns(words, layout)
        for k, v in cols.items():
            assert back[k].dtype == v.dtype
            np.testing.assert_array_equal(np.asarray(back[k]), np.asarray(v))
    """, devices=1)


def test_col_words():
    assert phys.col_words(np.float32) == 1
    assert phys.col_words(np.int32) == 1
    assert phys.col_words(np.bool_) == 1
    assert phys.col_words(np.int8) == 1
    assert phys.col_words(np.int16) == 1
    assert phys.col_words(np.int64) == 2
    assert phys.col_words(np.float64) == 2


# -- compact regressions (satellite) ------------------------------------------


class _SpyKernels:
    """Registry stand-in: anything with a ``prefix_sum`` attribute satisfies
    compact's kernel-set contract (attribute access only)."""

    def __init__(self, fn):
        self.prefix_sum = fn


def test_compact_empty_shard():
    """A zero-length shard short-circuits: no prefix scan runs, output is a
    zero-filled buffer with count 0 and no overflow."""
    def boom(_):
        raise AssertionError("prefix_sum must not run on empty input")

    cols = {"x": jnp.zeros((0,), jnp.float32),
            "w": jnp.zeros((0, 3), jnp.uint32)}      # packed-word matrix too
    out, cnt, ovf = phys.compact(cols, jnp.zeros((0,), jnp.bool_), 4,
                                 kernels=_SpyKernels(boom))
    assert out["x"].shape == (4,) and out["w"].shape == (4, 3)
    assert int(cnt) == 0 and not bool(ovf)


def test_compact_integer_keep_matches_bool_and_uses_kernel():
    """Integer 0/1 keep takes the same (registry prefix_sum) fast path as
    boolean keep."""
    calls = []

    def spy_prefix(x):
        calls.append(x.dtype)
        return jnp.cumsum(x)

    spy = _SpyKernels(spy_prefix)
    x = jnp.asarray(np.arange(8, dtype=np.float32))
    keep_b = jnp.asarray(np.array([1, 0, 1, 1, 0, 0, 1, 0], bool))
    keep_i = keep_b.astype(jnp.int32)
    out_b, cnt_b, _ = phys.compact({"x": x}, keep_b, 8, kernels=spy)
    out_i, cnt_i, _ = phys.compact({"x": x}, keep_i, 8, kernels=spy)
    assert len(calls) == 2 and all(d == jnp.int32 for d in calls)
    np.testing.assert_array_equal(np.asarray(out_b["x"]), np.asarray(out_i["x"]))
    assert int(cnt_b) == int(cnt_i) == 4


def test_compact_2d_values():
    """Trailing dims compact row-wise (the packed-word matrix path)."""
    w = jnp.asarray(np.arange(12, dtype=np.uint32).reshape(6, 2))
    keep = jnp.asarray(np.array([0, 1, 0, 1, 1, 0], bool))
    out, cnt, ovf = phys.compact({"w": w}, keep, 4)
    assert int(cnt) == 3 and not bool(ovf)
    np.testing.assert_array_equal(np.asarray(out["w"][:3]),
                                  np.asarray(w)[[1, 3, 4]])


def test_empty_table_pipeline():
    """End-to-end empty-shard compaction: a 0-row-surviving filter feeds
    sort and aggregate without tripping any scan/overflow machinery."""
    t = {"k": np.arange(16, dtype=np.int32),
         "x": np.ones(16, np.float32)}
    df = hf.table(t)
    empty = df[df["x"] < -1.0]
    assert empty.collect().num_rows() == 0
    a = hf.aggregate(empty, "k", s=hf.sum_(empty["x"]))
    assert a.collect().num_rows() == 0
    s = empty.sort("k")
    assert s.collect().num_rows() == 0
    run_sharded("""
        t = {"k": np.arange(16, dtype=np.int32),
             "x": np.ones(16, np.float32)}
        df = hf.table(t)
        empty = df[df["x"] < -1.0]
        a = hf.aggregate(empty, "k", s=hf.sum_(empty["x"]))
        assert a.collect().num_rows() == 0
        assert empty.sort("k").collect().num_rows() == 0
    """, devices=8)


# -- collective count: the 2-per-exchange guarantee ---------------------------


def _count_prim(closed_jaxpr, name: str) -> int:
    total = 0

    def walk(jx):
        nonlocal total
        for eqn in jx.eqns:
            if eqn.primitive.name == name:
                total += 1
            for v in eqn.params.values():
                vs = v if isinstance(v, (list, tuple)) else (v,)
                for x in vs:
                    if hasattr(x, "jaxpr"):
                        walk(x.jaxpr)
                    elif hasattr(x, "eqns"):
                        walk(x)

    walk(closed_jaxpr.jaxpr)
    return total


def count_all_to_all(lowered) -> int:
    fn, inputs = lowered._prepare()
    jaxpr = jax.make_jaxpr(lambda s, e: fn(s, e))(inputs["scans"],
                                                  inputs["ext"])
    return _count_prim(jaxpr, "all_to_all")


def test_wide_table_exchange_is_two_collectives():
    """Acceptance: a shuffle of a >=8-column table lowers to EXACTLY 2
    all_to_all per exchange (counts + packed payload); the per-column
    baseline pays 1 + n_columns.  Verified against the traced jaxpr on 8
    shards, not just the plan annotation."""
    run_sharded("""
        import jax.numpy as jnp

        def count_prim(closed_jaxpr, name):
            total = 0
            def walk(jx):
                nonlocal total
                for eqn in jx.eqns:
                    if eqn.primitive.name == name:
                        total += 1
                    for v in eqn.params.values():
                        vs = v if isinstance(v, (list, tuple)) else (v,)
                        for x in vs:
                            if hasattr(x, "jaxpr"): walk(x.jaxpr)
                            elif hasattr(x, "eqns"): walk(x)
            walk(closed_jaxpr.jaxpr)
            return total

        def count_a2a(lowered):
            fn, inputs = lowered._prepare()
            jaxpr = jax.make_jaxpr(lambda s, e: fn(s, e))(
                inputs["scans"], inputs["ext"])
            return count_prim(jaxpr, "all_to_all")

        rng = np.random.default_rng(3)
        n = 512
        t = {f"c{i}": rng.normal(size=n).astype(np.float32) for i in range(7)}
        t["k"] = rng.integers(0, 5, n).astype(np.int32)
        t["b"] = rng.normal(size=n) > 0          # 9 columns total
        df = hf.table(t)
        agg = {f"s{i}": hf.sum_(df[f"c{i}"]) for i in range(7)}
        a = hf.aggregate(df, "k", **agg)
        # partial_agg off isolates the packed-exchange claim: ONE exchange
        # of the 9-column table (well, 8 after pruning b) per plan.
        cfg_on = hf.ExecConfig(partial_agg=False)
        cfg_off = hf.ExecConfig(partial_agg=False, packed_exchange=False)
        pl = a.physical_plan(cfg_on)
        nex = pl.counts()["hash_exchanges"]
        assert nex == 1, pl.render()
        ncols = len([op for op in pl.ops
                     if type(op).__name__ == "HashExchange"][0].schema)
        assert ncols >= 8, ncols
        on = count_a2a(a.lower(cfg_on))
        off = count_a2a(a.lower(cfg_off))
        assert on == 2 * nex, (on, nex)
        assert off == (1 + ncols) * nex, (off, ncols)
        # the plan census agrees with the traced jaxpr
        assert pl.collective_count() == on
        assert a.physical_plan(cfg_off).collective_count() == off
    """, devices=8)


# -- A/B equivalence on 1/2/8 shards ------------------------------------------


_MIXED_BODY = """
    rng = np.random.default_rng(11)
    n, m = 600, 80
    left = {"k1": rng.integers(0, 7, n).astype(np.int32),
            "k2": rng.integers(0, 9, n).astype(np.int32),
            "x": rng.normal(size=n).astype(np.float32),
            "flag": rng.normal(size=n) > 0,
            "small": rng.integers(-100, 100, n).astype(np.int8)}
    right = {"ca": rng.integers(0, 7, m).astype(np.int32),
             "cb": rng.integers(0, 9, m).astype(np.int32),
             "w": rng.normal(size=m).astype(np.float32)}

    def run(cfg):
        l, r = hf.table(left), hf.table(right, "d")
        j = hf.join(l, r, on=[("k1", "ca"), ("k2", "cb")])
        s = j.sort(by=("k1", "k2"))
        return s.collect(cfg).to_numpy()

    a = run(hf.ExecConfig())
    b = run(hf.ExecConfig(packed_exchange=False))
    for k in a:
        assert a[k].dtype == b[k].dtype, k
        np.testing.assert_array_equal(a[k], b[k], err_msg=k)
    assert a["flag"].dtype == np.bool_
    assert a["small"].dtype == np.int8
"""


@pytest.mark.parametrize("devices", [1, 2, 8])
def test_packed_matches_unpacked_mixed_dtypes(devices):
    run_sharded(_MIXED_BODY, devices=devices)


def test_packed_rebalance_preserves_order():
    """Rebalance (the order-sensitive exchange user) is unchanged by
    packing: global row order survives on 8 shards."""
    run_sharded("""
        rng = np.random.default_rng(12)
        n = 500
        t = {"t": rng.permutation(n).astype(np.int32),
             "x": rng.normal(size=n).astype(np.float32),
             "b": rng.normal(size=n) > 0}
        s = hf.table(t).sort("t")
        out = hf.sma(s, s["x"], 3, out="m").collect().to_numpy()
        assert np.array_equal(out["t"], np.sort(t["t"]))
        order = np.argsort(t["t"])
        assert np.array_equal(out["b"], t["b"][order])
    """, devices=8)
